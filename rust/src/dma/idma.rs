//! iDMA baseline: a monolithic P2P DMA engine (Benz et al., TC'23).
//!
//! P2MP is software-emulated: one full P2P copy per destination, strictly
//! sequential ("cycles equal the sum of all P2P transfers", §IV-B). The
//! engine gathers the source pattern through its local DSE-equivalent and
//! pushes AXI write bursts; because the *destination* has no DSE, a
//! patterned destination layout must be expressed as one burst per
//! contiguous run — short runs mean short bursts and poor link
//! utilisation, which is exactly the gap Table I's "Addr. Gen" column
//! shows against distributed DMAs.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::axi::{split_bursts, IdPool};
use crate::mem::Scratchpad;
use crate::noc::{Message, NetPort, NodeId, Packet, FLIT_BYTES};

use super::torrent::dse::AffinePattern;
use super::{Engine, EngineCtx, SubmitError, TaskPhase, TaskResult, TaskSpec};

/// Descriptor-processing cycles per issued burst.
pub const IDMA_DESC_CYCLES: u64 = 2;
/// Outstanding AXI write window.
pub const IDMA_OUTSTANDING: usize = 8;

/// One P2MP job for the iDMA: same stream to every destination pattern.
#[derive(Debug, Clone)]
pub struct IdmaTask {
    pub task: u32,
    pub read: AffinePattern,
    pub dests: Vec<(NodeId, AffinePattern)>,
    pub with_data: bool,
}

#[derive(Debug)]
struct Active {
    task: IdmaTask,
    submitted_at: u64,
    /// Flattened (dest index, burst addr, stream offset, len) work list.
    bursts: VecDeque<(usize, u64, usize, usize)>,
    stream: Option<Arc<Vec<u8>>>,
    ids: IdPool,
    /// Read-side DSE budget in bytes.
    budget: f64,
    rate: f64,
    next_issue_at: u64,
    /// Index of the destination currently being served (sequential P2P).
    cur_dest: usize,
    /// Outstanding bursts of the current destination.
    inflight: usize,
    issued_bytes: usize,
}

/// The engine.
#[derive(Debug)]
pub struct Idma {
    pub node: NodeId,
    queue: VecDeque<(IdmaTask, u64)>,
    active: Option<Active>,
    pub results: Vec<TaskResult>,
}

impl Idma {
    pub fn new(node: NodeId) -> Self {
        Idma { node, queue: VecDeque::new(), active: None, results: Vec::new() }
    }

    pub fn submit(&mut self, task: IdmaTask, now: u64) {
        assert!(!task.dests.is_empty());
        for (_, p) in &task.dests {
            assert_eq!(p.total_bytes(), task.read.total_bytes());
        }
        self.queue.push_back((task, now));
    }

    pub fn is_idle(&self) -> bool {
        self.active.is_none() && self.queue.is_empty()
    }

    /// Activity hint (the `sim::Clocked::next_event` contract). While
    /// bursts remain the engine is busy every cycle — the read-DSE budget
    /// accrues per tick and feeds later issue decisions, so no cycle may
    /// be skipped. With the work list drained it only waits on AXI B
    /// responses (message-driven), which never needs a tick.
    pub fn next_event(&self, now: u64) -> Option<u64> {
        match &self.active {
            None => (!self.queue.is_empty()).then_some(now),
            Some(a) => (!a.bursts.is_empty()).then_some(now),
        }
    }

    /// Handle an AXI write response addressed to this engine.
    pub fn handle(&mut self, pkt: &Packet, now: u64) -> bool {
        let Message::AxiWriteResp { axi_id, ok } = pkt.msg else { return false };
        assert!(ok, "iDMA write burst failed");
        let Some(a) = self.active.as_mut() else { return true };
        a.ids.release(axi_id);
        a.inflight -= 1;
        // Transfer to the current destination completes when its bursts
        // are done; completion of the whole task when the work list and
        // windows drain.
        if a.bursts.is_empty() && a.inflight == 0 && a.issued_bytes == a.total_bytes() {
            let r = TaskResult {
                task: a.task.task,
                submitted_at: a.submitted_at,
                finished_at: now,
                bytes: a.task.read.total_bytes(),
                n_dests: a.task.dests.len(),
            };
            self.results.push(r);
            self.active = None;
        }
        true
    }

    pub fn tick(&mut self, net: &mut dyn NetPort, mem: &mut Scratchpad) {
        let now = net.cycle();
        if self.active.is_none() {
            if let Some((task, submitted_at)) = self.queue.pop_front() {
                let stream = task.with_data.then(|| Arc::new(task.read.gather(mem)));
                let mut bursts = VecDeque::new();
                for (di, (_, pat)) in task.dests.iter().enumerate() {
                    let mut off = 0;
                    for (addr, len) in pat.runs() {
                        for b in split_bursts(addr, len) {
                            bursts.push_back((di, b.addr, off, b.bytes));
                            off += b.bytes;
                        }
                    }
                }
                let rate = task.read.rate_per_cycle();
                self.active = Some(Active {
                    submitted_at: submitted_at.max(now),
                    bursts,
                    stream,
                    ids: IdPool::new(IDMA_OUTSTANDING),
                    budget: 0.0,
                    rate,
                    next_issue_at: now,
                    cur_dest: 0,
                    inflight: 0,
                    issued_bytes: 0,
                    task,
                });
            }
        }
        let Some(a) = self.active.as_mut() else { return };
        a.budget += a.rate;
        // Issue bursts: sequential per destination, windowed within one.
        while let Some(&(di, addr, off, len)) = a.bursts.front() {
            if now < a.next_issue_at || a.ids.is_exhausted() {
                break;
            }
            if di != a.cur_dest {
                // Next destination starts only when the previous fully
                // drained (sequential P2P semantics).
                if a.inflight > 0 {
                    break;
                }
                a.cur_dest = di;
            }
            if a.budget < len as f64 {
                break; // source read hasn't produced the bytes yet
            }
            a.budget -= len as f64;
            a.bursts.pop_front();
            let axi_id = a.ids.acquire().unwrap();
            let payload = a.stream.as_ref().map(|s| s[off..off + len].to_vec());
            let dst = a.task.dests[di].0;
            let mut pkt = Packet::new(
                0,
                self.node,
                dst,
                Message::AxiWriteReq { addr, bytes: len, axi_id },
            );
            pkt = match payload {
                Some(p) => pkt.with_payload(p),
                None => pkt.with_phantom_payload(len),
            };
            net.send(self.node, pkt);
            a.inflight += 1;
            a.issued_bytes += len;
            a.next_issue_at = now + IDMA_DESC_CYCLES + (len as u64).div_ceil(FLIT_BYTES as u64);
        }
    }
}

impl Active {
    fn total_bytes(&self) -> usize {
        self.task.read.total_bytes() * self.task.dests.len()
    }
}

/// Uniform dispatch surface; delegates to the inherent methods above.
impl Engine for Idma {
    fn label(&self) -> &'static str {
        "idma"
    }

    fn submit(&mut self, spec: TaskSpec, now: u64) -> Result<(), SubmitError> {
        spec.validate()?;
        let TaskSpec { task, read, dests, with_data, .. } = spec;
        Idma::submit(self, IdmaTask { task, read, dests, with_data }, now);
        Ok(())
    }

    fn handle(&mut self, pkt: &Packet, _ctx: &mut EngineCtx<'_>, now: u64) -> bool {
        Idma::handle(self, pkt, now)
    }

    fn tick(&mut self, ctx: &mut EngineCtx<'_>) {
        Idma::tick(self, ctx.net, ctx.mem)
    }

    fn next_event(&self, now: u64) -> Option<u64> {
        Idma::next_event(self, now)
    }

    fn is_idle(&self) -> bool {
        Idma::is_idle(self)
    }

    fn drain_results(&mut self) -> Vec<TaskResult> {
        std::mem::take(&mut self.results)
    }

    fn peek_result(&self, task: u32) -> Option<&TaskResult> {
        self.results.iter().find(|r| r.task == task)
    }

    fn phase_of(&self, task: u32, _now: u64) -> Option<TaskPhase> {
        if self.queue.iter().any(|(t, _)| t.task == task) {
            // Descriptor expansion has not started yet.
            return Some(TaskPhase::Configuring);
        }
        self.active
            .as_ref()
            .filter(|a| a.task.task == task)
            .map(|_| TaskPhase::Streaming)
    }
}

//! Serving telemetry (ISSUE 8): log-bucketed latency histograms with
//! exact tail percentiles, and admission/occupancy time-series.
//!
//! The histogram keeps both a 64-bucket log2 shape (for display: bucket
//! `i` covers `[2^i, 2^(i+1))` cycles, bucket 0 covers `{0, 1}`) and the
//! raw samples, so p50/p99/p999 are *exact* nearest-rank order
//! statistics, not bucket interpolations — at serving scale the p999 of
//! a log-bucketed estimate can be off by half a bucket (~40%), which is
//! bigger than the effects the sweep measures.

/// Latency histogram: log2 display buckets + exact percentile samples.
#[derive(Debug, Clone, Default)]
pub struct LatencyHisto {
    buckets: [u64; 64],
    samples: Vec<u64>,
}

impl LatencyHisto {
    pub fn new() -> Self {
        LatencyHisto { buckets: [0; 64], samples: Vec::new() }
    }

    pub fn record(&mut self, latency: u64) {
        let idx = (64 - latency.max(1).leading_zeros() as usize - 1).min(63);
        self.buckets[idx] += 1;
        self.samples.push(latency);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Exact nearest-rank percentile (`q` in [0, 100]); `None` when
    /// empty. p50/p99/p999 below are the report fields.
    pub fn percentile(&self, q: f64) -> Option<u64> {
        if self.samples.is_empty() {
            return None;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        let rank = ((q / 100.0) * sorted.len() as f64).ceil() as usize;
        Some(sorted[rank.clamp(1, sorted.len()) - 1])
    }

    pub fn p50(&self) -> Option<u64> {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> Option<u64> {
        self.percentile(99.0)
    }

    pub fn p999(&self) -> Option<u64> {
        self.percentile(99.9)
    }

    pub fn mean(&self) -> Option<f64> {
        if self.samples.is_empty() {
            return None;
        }
        Some(self.samples.iter().sum::<u64>() as f64 / self.samples.len() as f64)
    }

    pub fn max(&self) -> Option<u64> {
        self.samples.iter().copied().max()
    }

    /// Non-empty log2 buckets as `(bucket_floor_cycles, count)`, for the
    /// Markdown histogram rendering.
    pub fn shape(&self) -> Vec<(u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (1u64 << i, c))
            .collect()
    }
}

/// One occupancy sample on the driver's fixed cadence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Sample {
    pub cycle: u64,
    /// Requests waiting in the admission queue.
    pub pending: usize,
    /// Admitted-but-incomplete requests.
    pub inflight: usize,
    /// Cumulative admitted arrivals.
    pub admitted: u64,
    /// Cumulative rejected arrivals.
    pub rejected: u64,
}

/// Fabric utilization over a window: router lane-activity delta
/// normalized per router per cycle. A router can move several flits per
/// cycle (one per output lane), so this is an activity index — 0 means
/// a quiet fabric, and the sweep reads it for the saturation knee, not
/// as a percentage.
pub fn utilization(activity_delta: u64, n_nodes: usize, cycles: u64) -> f64 {
    if cycles == 0 || n_nodes == 0 {
        return 0.0;
    }
    activity_delta as f64 / (n_nodes as f64 * cycles as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_are_exact_order_statistics() {
        let mut h = LatencyHisto::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.p50(), Some(500));
        assert_eq!(h.p99(), Some(990));
        assert_eq!(h.p999(), Some(999));
        assert_eq!(h.percentile(100.0), Some(1000));
        assert_eq!(h.max(), Some(1000));
        assert!((h.mean().unwrap() - 500.5).abs() < 1e-9);
    }

    #[test]
    fn single_sample_is_every_percentile() {
        let mut h = LatencyHisto::new();
        h.record(42);
        assert_eq!(h.p50(), Some(42));
        assert_eq!(h.p999(), Some(42));
    }

    #[test]
    fn empty_histogram_has_no_percentiles() {
        let h = LatencyHisto::new();
        assert_eq!(h.p50(), None);
        assert_eq!(h.mean(), None);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn log_buckets_cover_the_tail() {
        let mut h = LatencyHisto::new();
        h.record(0); // clamps into bucket 0
        h.record(1);
        h.record(2);
        h.record(3);
        h.record(1024);
        h.record(u64::MAX); // must not index out of bounds
        let shape = h.shape();
        assert_eq!(shape[0], (1, 2)); // {0, 1}
        assert_eq!(shape[1], (2, 2)); // {2, 3}
        assert!(shape.contains(&(1024, 1)));
        assert!(shape.contains(&(1u64 << 63, 1)));
    }

    #[test]
    fn utilization_normalizes_per_router_cycle() {
        assert!((utilization(1600, 16, 100) - 1.0).abs() < 1e-9);
        assert_eq!(utilization(5, 16, 0), 0.0);
        assert!(utilization(800, 16, 100) < utilization(1600, 16, 100));
    }
}

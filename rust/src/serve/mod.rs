//! Open-loop serving simulator (ISSUE 8 tentpole): a multi-tenant
//! inference service modeled on the simulated SoC.
//!
//! The closed-loop drivers elsewhere in the repo submit a batch and
//! drain to quiescence; a serving stack never quiesces. This module
//! drives the [`crate::coordinator::Coordinator`] open-loop: a seeded
//! [`arrival::ArrivalGen`] produces request times regardless of system
//! state, a workload mix turns each into either a chainwrite multicast
//! of an attention KV block (the paper's DeepSeek-V3 pattern: one
//! engine's KV pushed to the engine regions that attend over it) or
//! unicast iDMA background traffic, an [`admission::Admission`]
//! controller bounds what enters, a [`batch::Batcher`] coalesces
//! compatible KV requests inside a batching window, and
//! [`stats::LatencyHisto`] + occupancy [`stats::Sample`]s record what
//! the clients saw. The question answered is tail latency vs offered
//! load, up to and past saturation.
//!
//! # Determinism
//!
//! The driver is bit-identical across [`crate::sim::StepMode`]s because
//! every decision it makes is a function of (a) the seed — arrivals and
//! the mix draw from their own [`crate::util::stream`]s — and (b)
//! engine-reported completion cycles, which are bit-exact across modes.
//! Stepping happens only through [`Coordinator::run_for`], whose
//! bounded-horizon landing is exact in every mode, and driver events at
//! a wake cycle are processed in one fixed order: completions, then due
//! retries, then arrivals, then the admission pump, then batch flushes,
//! then occupancy samples. `rust/tests/serving.rs` enforces this three
//! ways (FullTick / EventDriven / Parallel) on three fabrics.
//!
//! # Resilience (ISSUE 9)
//!
//! [`Coordinator::run_for`] ticks the fault watcher, so when the SoC
//! carries an armed [`crate::sim::FaultPlan`] the serving loop detects
//! mid-stream stalls, repairs them (with partial-transfer resume and
//! path-diverse reroute when the plan arms them), and the client-facing
//! dispositions record what survived. On top, an optional
//! [`admission::RetryPolicy`] re-offers rejected or failed requests
//! after a bounded exponential backoff with seeded jitter drawn from
//! [`crate::util::stream::RETRY`] — a pure function of (seed, request,
//! attempt), so retried runs replay bit-identically across step modes.
//! Retried requests keep their original `arrived` cycle: retry wait is
//! client-visible latency, exactly like queue wait.

pub mod admission;
pub mod arrival;
pub mod batch;
pub mod report;
pub mod stats;

pub use admission::{Admission, AdmissionPolicy, RejectKind, RetryPolicy, Verdict};
pub use arrival::{ArrivalGen, ArrivalKind};
pub use batch::{Batch, Batcher};
pub use report::{
    resilience_json, resilience_markdown, sweep_json, sweep_markdown, ResilienceRow,
    ServeSweepRow,
};
pub use stats::{LatencyHisto, Sample};

use std::collections::BTreeMap;

use crate::coordinator::{Coordinator, EngineKind, TaskId, TaskOutcome};
use crate::noc::NodeId;
use crate::sched::Strategy;
use crate::util::{self, stream};

/// Workload mix: what an arrival is, sized so every request passes
/// simple-mode submission (`bytes <= spm/2`) by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MixConfig {
    /// Percent of arrivals that are KV multicasts (the rest are
    /// background unicasts).
    pub mcast_pct: u64,
    /// KV block size per destination (bytes).
    pub kv_bytes: usize,
    /// KV destination-set size range, inclusive.
    pub kv_dests_lo: usize,
    pub kv_dests_hi: usize,
    /// KV blocks originate from the first N nodes (the "attention
    /// engines"); background traffic uses the whole fabric.
    pub kv_sources: usize,
    /// Background unicast transfer size (bytes).
    pub bg_bytes: usize,
}

impl Default for MixConfig {
    fn default() -> Self {
        MixConfig {
            mcast_pct: 70,
            kv_bytes: 4 * 1024,
            kv_dests_lo: 2,
            kv_dests_hi: 4,
            kv_sources: 4,
            bg_bytes: 1024,
        }
    }
}

/// Request class drawn from the mix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReqClass {
    /// Chainwrite multicast of one KV block.
    Kv,
    /// Unicast iDMA background transfer.
    Background,
}

impl ReqClass {
    pub fn as_str(self) -> &'static str {
        match self {
            ReqClass::Kv => "kv",
            ReqClass::Background => "background",
        }
    }
}

/// One generated request (driver-side; becomes part of an engine task
/// only if admitted).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub id: u32,
    pub arrived: u64,
    pub class: ReqClass,
    pub src: NodeId,
    pub dests: Vec<NodeId>,
    pub bytes: usize,
}

/// Terminal state of one request, as the client saw it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Served; latency is arrival → engine-reported finish (queue wait
    /// and batching wait included — that is the client clock).
    Completed { latency: u64 },
    /// Dropped by admission control.
    Rejected(RejectKind),
    /// Admitted but closed without completing (fault machinery).
    Failed,
    /// Still somewhere in the pipeline when the run ended.
    Unfinished,
}

/// Per-request terminal record; the cross-StepMode differential suite
/// compares these vectors bit-for-bit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Disposition {
    pub req: u32,
    pub arrived: u64,
    pub class: ReqClass,
    pub outcome: Outcome,
}

/// Full configuration of one open-loop run.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub seed: u64,
    /// Injection horizon: arrivals stop after this many cycles.
    pub horizon: u64,
    /// Extra cycle budget to drain admitted work after the horizon;
    /// whatever is still unresolved then is reported `Unfinished`.
    pub drain: u64,
    pub arrival: ArrivalKind,
    pub policy: AdmissionPolicy,
    /// Bound on admitted-but-incomplete requests.
    pub max_inflight: usize,
    /// Pending-queue bound (policy `queue` only).
    pub queue_cap: usize,
    /// Batching window in cycles (0 = no coalescing).
    pub batch_window: u64,
    /// Occupancy sampling cadence in cycles.
    pub sample_every: u64,
    /// Chain-order strategy for KV multicasts.
    pub strategy: Strategy,
    pub mix: MixConfig,
    /// Client-side retry for rejected/failed requests (off by default).
    pub retry: RetryPolicy,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            seed: 1,
            horizon: 20_000,
            drain: 60_000,
            arrival: ArrivalKind::Poisson { rate_per_kcycle: 4 },
            policy: AdmissionPolicy::Queue,
            max_inflight: 8,
            queue_cap: 16,
            batch_window: 64,
            sample_every: 1_000,
            strategy: Strategy::Greedy,
            mix: MixConfig::default(),
            retry: RetryPolicy::default(),
        }
    }
}

/// What one open-loop run measured.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub offered: u64,
    pub admitted: u64,
    pub rejected_shed: u64,
    pub rejected_queue_full: u64,
    pub completed: u64,
    pub failed: u64,
    pub unfinished: u64,
    /// Engine tasks actually submitted (≤ admitted: batching coalesces).
    pub tasks_submitted: u64,
    /// Total cycles stepped (horizon + drain actually used).
    pub cycles: u64,
    pub histo: LatencyHisto,
    pub samples: Vec<Sample>,
    /// Fabric utilization over the run: activity normalized by the
    /// topology's aggregate port capacity, always in `[0, 1]`
    /// ([`stats::utilization`]).
    pub util: f64,
    pub pending_peak: usize,
    pub inflight_peak: usize,
    /// Destination-bytes actually delivered: `bytes * n_dests` for clean
    /// completions, the served fraction for repaired tasks.
    pub goodput_bytes: u64,
    /// Distinct requests retried at least once.
    pub retried: u64,
    /// Total retry re-offers across all requests.
    pub retry_attempts: u64,
    /// Engine tasks that terminated as Repaired (fault machinery).
    pub repaired_tasks: u64,
    /// Bytes re-streamed by repair chains (0 when resume salvaged
    /// everything or no fault fired).
    pub restreamed_bytes: u64,
    /// Terminal record per request, in request-id order.
    pub dispositions: Vec<Disposition>,
}

impl ServeReport {
    pub fn rejected(&self) -> u64 {
        self.rejected_shed + self.rejected_queue_full
    }

    /// Percentile helpers defaulting to 0 when nothing completed (the
    /// saturated-shed corner of the sweep).
    pub fn p50(&self) -> u64 {
        self.histo.p50().unwrap_or(0)
    }

    pub fn p99(&self) -> u64 {
        self.histo.p99().unwrap_or(0)
    }

    pub fn p999(&self) -> u64 {
        self.histo.p999().unwrap_or(0)
    }

    /// Fraction of offered requests that completed — the availability
    /// number the resilience sweep compares across fault policies.
    pub fn availability(&self) -> f64 {
        if self.offered == 0 {
            return 1.0;
        }
        self.completed as f64 / self.offered as f64
    }
}

/// The open-loop driver. Owns all serving-layer state; the coordinator
/// (and through it the step mode, topology, and fault plan) is the
/// caller's.
pub struct ServeSim {
    cfg: ServeConfig,
    c: Coordinator,
    arrivals: ArrivalGen,
    mix_rng: util::rng::Rng,
    admission: Admission,
    batcher: Batcher,
    requests: Vec<Request>,
    outcomes: Vec<Option<Outcome>>,
    /// Submitted engine tasks → member request ids sharing completion.
    outstanding: Vec<(TaskId, Vec<u32>)>,
    /// Retry schedule: release cycle → request ids (BTreeMap so due
    /// retries drain in deterministic cycle-then-insertion order).
    retry_queue: BTreeMap<u64, Vec<u32>>,
    /// Retries scheduled so far, per request.
    attempts: Vec<u32>,
    /// Whether the request ever took an inflight slot (so `admitted`
    /// counts requests, not admission events, under retry).
    ever_admitted: Vec<bool>,
    tasks_submitted: u64,
    admitted: u64,
    rejected_shed: u64,
    rejected_queue_full: u64,
    goodput_bytes: u64,
    retried: u64,
    retry_attempts: u64,
    repaired_tasks: u64,
    restreamed_bytes: u64,
    samples: Vec<Sample>,
    pending_peak: usize,
    inflight_peak: usize,
}

impl ServeSim {
    pub fn new(cfg: ServeConfig, c: Coordinator) -> Self {
        let n_nodes = c.soc.cfg.n_nodes();
        assert!(n_nodes >= 2, "serving needs at least two nodes");
        let mix = cfg.mix;
        assert!(
            (1..n_nodes).contains(&mix.kv_dests_lo)
                && mix.kv_dests_lo <= mix.kv_dests_hi
                && mix.kv_dests_hi <= n_nodes - 1,
            "KV destination range [{}, {}] does not fit a {n_nodes}-node fabric",
            mix.kv_dests_lo,
            mix.kv_dests_hi,
        );
        assert!(mix.mcast_pct <= 100, "mcast_pct is a percentage");
        let arrivals = ArrivalGen::new(cfg.arrival, cfg.seed);
        let mix_rng = util::rng(cfg.seed, stream::MIX);
        let admission = Admission::new(cfg.policy, cfg.max_inflight, cfg.queue_cap);
        let batcher = Batcher::new(cfg.batch_window);
        ServeSim {
            cfg,
            c,
            arrivals,
            mix_rng,
            admission,
            batcher,
            requests: Vec::new(),
            outcomes: Vec::new(),
            outstanding: Vec::new(),
            retry_queue: BTreeMap::new(),
            attempts: Vec::new(),
            ever_admitted: Vec::new(),
            tasks_submitted: 0,
            admitted: 0,
            rejected_shed: 0,
            rejected_queue_full: 0,
            goodput_bytes: 0,
            retried: 0,
            retry_attempts: 0,
            repaired_tasks: 0,
            restreamed_bytes: 0,
            samples: Vec::new(),
            pending_peak: 0,
            inflight_peak: 0,
        }
    }

    /// Run the full open-loop scenario and consume the driver.
    pub fn run(mut self) -> ServeReport {
        let n_nodes = self.c.soc.cfg.n_nodes();
        let start = self.c.soc.cycle();
        let act_base: u64 =
            (0..n_nodes).map(|n| self.c.soc.net.router_activity(NodeId(n))).sum();
        let horizon = start + self.cfg.horizon;
        let mut next_sample = start + self.cfg.sample_every;

        // Injection phase: wake at the next driver event, step the SoC
        // exactly to it, process events in the fixed order.
        loop {
            let now = self.c.soc.cycle();
            let mut wake: Option<u64> = None;
            let mut fold = |t: u64| wake = Some(wake.map_or(t, |w: u64| w.min(t)));
            if self.arrivals.peek() <= horizon {
                fold(self.arrivals.peek());
            }
            if let Some(f) = self.batcher.next_flush() {
                fold(f);
            }
            if let Some((&at, _)) = self.retry_queue.iter().next() {
                fold(at);
            }
            if next_sample <= horizon {
                fold(next_sample);
            }
            let Some(wake) = wake else { break };
            debug_assert!(wake > now, "driver wake must advance time");
            if wake > now {
                self.c.run_for(wake - now);
            }
            let now = self.c.soc.cycle();
            self.collect_completions(now);
            self.release_retries(now);
            while self.arrivals.peek() <= now && self.arrivals.peek() <= horizon {
                let arrived = self.arrivals.pop();
                self.inject(arrived, now);
            }
            self.pump(now);
            self.flush_due(now);
            while next_sample <= now && next_sample <= horizon {
                self.sample(next_sample);
                next_sample += self.cfg.sample_every;
            }
            self.note_peaks();
        }

        // Drain phase: no new arrivals; close batches immediately and
        // keep stepping in fixed chunks until everything admitted
        // resolves or the drain budget expires.
        let drain_deadline = horizon + self.cfg.drain;
        loop {
            let now = self.c.soc.cycle();
            self.collect_completions(now);
            self.release_retries(now);
            self.pump(now);
            let open = self.batcher.flush_all();
            for b in open {
                self.submit_batch(&b);
            }
            self.note_peaks();
            if self.outstanding.is_empty()
                && self.admission.pending() == 0
                && self.retry_queue.is_empty()
            {
                break;
            }
            if now >= drain_deadline {
                break;
            }
            let chunk = 256.min(drain_deadline - now);
            self.c.run_for(chunk);
        }

        // Whatever is left never resolved inside the budget.
        let mut unfinished = 0u64;
        for o in &mut self.outcomes {
            if o.is_none() {
                *o = Some(Outcome::Unfinished);
                unfinished += 1;
            }
        }

        let end = self.c.soc.cycle();
        let act_now: u64 =
            (0..n_nodes).map(|n| self.c.soc.net.router_activity(NodeId(n))).sum();
        let capacity = stats::fabric_port_capacity(&self.c.soc.topo());
        let util = stats::utilization(act_now - act_base, capacity, end - start);
        let mut completed = 0u64;
        let mut failed = 0u64;
        let mut histo = LatencyHisto::new();
        let dispositions: Vec<Disposition> = self
            .requests
            .iter()
            .zip(&self.outcomes)
            .map(|(r, o)| {
                let outcome = o.expect("every request has a terminal outcome");
                match outcome {
                    Outcome::Completed { latency } => {
                        completed += 1;
                        histo.record(latency);
                    }
                    Outcome::Failed => failed += 1,
                    _ => {}
                }
                Disposition { req: r.id, arrived: r.arrived, class: r.class, outcome }
            })
            .collect();
        ServeReport {
            offered: self.requests.len() as u64,
            admitted: self.admitted,
            rejected_shed: self.rejected_shed,
            rejected_queue_full: self.rejected_queue_full,
            completed,
            failed,
            unfinished,
            tasks_submitted: self.tasks_submitted,
            cycles: end - start,
            histo,
            samples: self.samples,
            util,
            pending_peak: self.pending_peak,
            inflight_peak: self.inflight_peak,
            goodput_bytes: self.goodput_bytes,
            retried: self.retried,
            retry_attempts: self.retry_attempts,
            repaired_tasks: self.repaired_tasks,
            restreamed_bytes: self.restreamed_bytes,
            dispositions,
        }
    }

    /// Draw one request from the mix and offer it to admission.
    fn inject(&mut self, arrived: u64, now: u64) {
        let n_nodes = self.c.soc.cfg.n_nodes();
        let mix = self.cfg.mix;
        let id = self.requests.len() as u32;
        let req = if self.mix_rng.below(100) < mix.mcast_pct {
            let src = NodeId(self.mix_rng.index(mix.kv_sources.clamp(1, n_nodes)));
            let n_d =
                self.mix_rng.range(mix.kv_dests_lo as u64, mix.kv_dests_hi as u64) as usize;
            let dests: Vec<NodeId> = self
                .mix_rng
                .sample_distinct(n_nodes - 1, n_d)
                .into_iter()
                .map(|i| NodeId(if i >= src.0 { i + 1 } else { i }))
                .collect();
            Request { id, arrived, class: ReqClass::Kv, src, dests, bytes: mix.kv_bytes }
        } else {
            let src = NodeId(self.mix_rng.index(n_nodes));
            let d = self.mix_rng.index(n_nodes - 1);
            let dst = NodeId(if d >= src.0 { d + 1 } else { d });
            Request {
                id,
                arrived,
                class: ReqClass::Background,
                src,
                dests: vec![dst],
                bytes: mix.bg_bytes,
            }
        };
        self.requests.push(req);
        self.outcomes.push(None);
        self.attempts.push(0);
        self.ever_admitted.push(false);
        self.offer(id, now);
    }

    /// Offer one request (fresh or retried) to admission control.
    fn offer(&mut self, id: u32, now: u64) {
        match self.admission.offer(id) {
            Verdict::Admit => {
                self.note_admitted(id);
                self.dispatch(id, now);
            }
            Verdict::Enqueue => {} // released later by pump()
            Verdict::Reject(kind) => self.reject_or_retry(id, kind, now),
        }
    }

    /// `admitted` counts requests that ever held an inflight slot, so a
    /// request re-admitted after a failed attempt is not double-counted.
    fn note_admitted(&mut self, id: u32) {
        if !self.ever_admitted[id as usize] {
            self.ever_admitted[id as usize] = true;
            self.admitted += 1;
        }
    }

    /// A rejected request either schedules a retry or terminates.
    fn reject_or_retry(&mut self, id: u32, kind: RejectKind, now: u64) {
        if self.try_schedule_retry(id, now) {
            return;
        }
        match kind {
            RejectKind::Shed => self.rejected_shed += 1,
            RejectKind::QueueFull => self.rejected_queue_full += 1,
        }
        self.outcomes[id as usize] = Some(Outcome::Rejected(kind));
    }

    /// Schedule the next retry for `id` if its budget allows; returns
    /// false when exhausted (the caller records a terminal outcome).
    /// The delay is exponential backoff plus jitter drawn from a stream
    /// keyed only by (seed, request, attempt) — independent of event
    /// interleaving, so replay is exact.
    fn try_schedule_retry(&mut self, id: u32, now: u64) -> bool {
        let p = self.cfg.retry;
        if !p.enabled() || self.attempts[id as usize] >= p.max_attempts {
            return false;
        }
        self.attempts[id as usize] += 1;
        let attempt = self.attempts[id as usize];
        if attempt == 1 {
            self.retried += 1;
        }
        let backoff = p.backoff_for(attempt).max(1);
        let jitter = util::rng(
            self.cfg.seed,
            stream::RETRY + ((attempt as u64) << 32) + id as u64,
        )
        .below(backoff);
        self.retry_queue.entry(now + backoff + jitter).or_default().push(id);
        true
    }

    /// Re-offer retries whose backoff expired.
    fn release_retries(&mut self, now: u64) {
        loop {
            match self.retry_queue.iter().next() {
                Some((&at, _)) if at <= now => {}
                _ => break,
            }
            let (at, ids) = self.retry_queue.pop_first().expect("peeked above");
            debug_assert!(at <= now);
            for id in ids {
                self.retry_attempts += 1;
                self.offer(id, now);
            }
        }
    }

    /// Release queued requests into freed slots and dispatch them.
    fn pump(&mut self, now: u64) {
        for id in self.admission.pump() {
            self.note_admitted(id);
            self.dispatch(id, now);
        }
    }

    /// Route one admitted request: KV multicasts stage into the batcher
    /// (or submit directly when the window is 0 — same-cycle stages
    /// would still merge, and `batch_window = 0` must mean literally no
    /// coalescing), background unicasts go straight to the iDMA engine.
    fn dispatch(&mut self, id: u32, now: u64) {
        let req = self.requests[id as usize].clone();
        match req.class {
            ReqClass::Kv if self.cfg.batch_window > 0 => {
                self.batcher.stage(id, req.src, &req.dests, req.bytes, now);
            }
            ReqClass::Kv => {
                let h = self
                    .c
                    .submit_simple(
                        req.src,
                        &req.dests,
                        req.bytes,
                        EngineKind::Torrent(self.cfg.strategy),
                        false,
                    )
                    .expect("serve KV request valid by construction");
                self.tasks_submitted += 1;
                self.outstanding.push((h.id(), vec![id]));
            }
            ReqClass::Background => {
                let h = self
                    .c
                    .submit_simple(req.src, &req.dests, req.bytes, EngineKind::Idma, false)
                    .expect("serve background request valid by construction");
                self.tasks_submitted += 1;
                self.outstanding.push((h.id(), vec![id]));
            }
        }
    }

    /// Submit batches whose window expired.
    fn flush_due(&mut self, now: u64) {
        for b in self.batcher.flush_due(now) {
            self.submit_batch(&b);
        }
    }

    fn submit_batch(&mut self, b: &Batch) {
        let h = self
            .c
            .submit_simple(
                b.src,
                &b.dests,
                b.bytes,
                EngineKind::Torrent(self.cfg.strategy),
                false,
            )
            .expect("serve KV batch valid by construction");
        self.tasks_submitted += 1;
        self.outstanding.push((h.id(), b.members.clone()));
    }

    /// Drain finished tasks: latency clocks from each member request's
    /// *arrival* to the engine-reported finish cycle (queue, batching
    /// and retry wait included), so the number is mode-independent —
    /// both ends are bit-exact simulator state, not driver observation
    /// times. Repaired tasks complete their members (goodput counts the
    /// served fraction); failed tasks release their members into the
    /// retry path when the policy allows.
    fn collect_completions(&mut self, now: u64) {
        let outstanding = std::mem::take(&mut self.outstanding);
        let mut keep = Vec::with_capacity(outstanding.len());
        for (tid, members) in outstanding {
            // Extract plain data first so the record borrow ends before
            // the retry bookkeeping below takes `&mut self`.
            let (done, failed) = {
                let rec = self.c.record(tid).expect("outstanding task has a record");
                match (&rec.result, &rec.outcome) {
                    (Some(res), outcome) => {
                        let (goodput, restreamed) = match outcome {
                            Some(TaskOutcome::Repaired {
                                served_bytes,
                                restreamed_bytes,
                                ..
                            }) => (*served_bytes, Some(*restreamed_bytes)),
                            _ => ((res.bytes * res.n_dests) as u64, None),
                        };
                        (Some((res.finished_at, goodput, restreamed)), false)
                    }
                    (None, Some(TaskOutcome::Failed { .. })) => (None, true),
                    _ => (None, false),
                }
            };
            if let Some((finished_at, goodput, restreamed)) = done {
                self.goodput_bytes += goodput;
                if let Some(r) = restreamed {
                    self.repaired_tasks += 1;
                    self.restreamed_bytes += r;
                }
                for &m in &members {
                    let lat = finished_at.saturating_sub(self.requests[m as usize].arrived);
                    self.outcomes[m as usize] = Some(Outcome::Completed { latency: lat });
                    self.admission.release();
                }
            } else if failed {
                for &m in &members {
                    self.admission.release();
                    if !self.try_schedule_retry(m, now) {
                        self.outcomes[m as usize] = Some(Outcome::Failed);
                    }
                }
            } else {
                keep.push((tid, members));
            }
        }
        self.outstanding = keep;
    }

    fn sample(&mut self, cycle: u64) {
        self.samples.push(Sample {
            cycle,
            pending: self.admission.pending(),
            inflight: self.admission.inflight(),
            admitted: self.admitted,
            rejected: self.rejected_shed + self.rejected_queue_full,
        });
    }

    fn note_peaks(&mut self) {
        self.pending_peak = self.pending_peak.max(self.admission.pending());
        self.inflight_peak = self.inflight_peak.max(self.admission.inflight());
    }
}

/// Convenience: build a coordinator and run one scenario.
pub fn run(
    cfg: ServeConfig,
    soc_cfg: crate::soc::SocConfig,
    mode: crate::sim::StepMode,
) -> ServeReport {
    ServeSim::new(cfg, Coordinator::with_step_mode(soc_cfg, mode)).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::StepMode;
    use crate::soc::SocConfig;

    fn quick_cfg(rate: u64, policy: AdmissionPolicy) -> ServeConfig {
        ServeConfig {
            seed: 11,
            horizon: 4_000,
            drain: 30_000,
            arrival: ArrivalKind::Poisson { rate_per_kcycle: rate },
            policy,
            ..ServeConfig::default()
        }
    }

    fn fabric() -> SocConfig {
        SocConfig::custom(4, 4, 64 * 1024)
    }

    #[test]
    fn accounting_is_conserved() {
        let r = run(quick_cfg(6, AdmissionPolicy::Queue), fabric(), StepMode::EventDriven);
        assert!(r.offered > 0, "no arrivals inside the horizon");
        assert_eq!(r.offered, r.admitted + r.rejected(), "offered != admitted + rejected");
        assert_eq!(
            r.admitted,
            r.completed + r.failed + r.unfinished,
            "admitted requests must reach a terminal state"
        );
        assert_eq!(r.dispositions.len(), r.offered as usize);
        assert_eq!(r.histo.count() as u64, r.completed);
        assert!(r.tasks_submitted <= r.admitted, "batching can only reduce task count");
        assert!(r.util > 0.0, "a served run must move flits");
    }

    #[test]
    fn replays_identically_by_seed() {
        let a = run(quick_cfg(8, AdmissionPolicy::Queue), fabric(), StepMode::EventDriven);
        let b = run(quick_cfg(8, AdmissionPolicy::Queue), fabric(), StepMode::EventDriven);
        assert_eq!(a.dispositions, b.dispositions);
        assert_eq!(a.samples, b.samples);
        assert_eq!(a.cycles, b.cycles);
    }

    #[test]
    fn low_load_completes_everything() {
        let r = run(quick_cfg(1, AdmissionPolicy::Queue), fabric(), StepMode::EventDriven);
        assert_eq!(r.rejected(), 0, "1/kcycle must not saturate a 4x4 fabric");
        assert_eq!(r.unfinished, 0, "drain budget too small for trickle load");
        assert_eq!(r.completed, r.offered);
    }

    #[test]
    fn saturation_sheds_under_shed_policy() {
        // 60 arrivals/kcycle on max_inflight=8 is far past saturation:
        // the shed policy must reject and never queue.
        let mut cfg = quick_cfg(60, AdmissionPolicy::Shed);
        cfg.queue_cap = 0;
        let r = run(cfg, fabric(), StepMode::EventDriven);
        assert!(r.rejected_shed > 0, "overload never shed");
        assert_eq!(r.rejected_queue_full, 0);
        assert_eq!(r.pending_peak, 0, "shed policy must not queue");
        assert!(r.inflight_peak <= 8);
    }

    #[test]
    fn backpressure_never_rejects_and_queues_deep() {
        let r = run(quick_cfg(60, AdmissionPolicy::Backpressure), fabric(), StepMode::EventDriven);
        assert_eq!(r.rejected(), 0, "backpressure must never reject");
        assert!(r.pending_peak > 16, "overload should build a deep queue");
    }

    #[test]
    fn queue_policy_bounds_the_queue() {
        let mut cfg = quick_cfg(60, AdmissionPolicy::Queue);
        cfg.queue_cap = 5;
        let r = run(cfg, fabric(), StepMode::EventDriven);
        assert!(r.pending_peak <= 5, "queue exceeded its cap");
        assert!(r.rejected_queue_full > 0, "overload never overflowed the queue");
    }

    #[test]
    fn batching_coalesces_under_load() {
        // Many KV requests from few sources inside a wide window must
        // produce fewer engine tasks than requests.
        let mut cfg = quick_cfg(40, AdmissionPolicy::Backpressure);
        cfg.batch_window = 256;
        cfg.mix.mcast_pct = 100;
        cfg.mix.kv_sources = 2;
        let r = run(cfg, fabric(), StepMode::EventDriven);
        assert!(
            r.tasks_submitted < r.admitted,
            "no coalescing: {} tasks for {} admitted",
            r.tasks_submitted,
            r.admitted
        );
    }

    #[test]
    fn zero_window_means_no_coalescing() {
        let mut cfg = quick_cfg(20, AdmissionPolicy::Queue);
        cfg.batch_window = 0;
        let r = run(cfg, fabric(), StepMode::EventDriven);
        assert_eq!(r.tasks_submitted, r.admitted);
    }

    #[test]
    fn goodput_counts_delivered_destination_bytes() {
        // All-background trickle: every request is a 1024-byte unicast,
        // so goodput is exactly completed * 1024.
        let mut cfg = quick_cfg(1, AdmissionPolicy::Queue);
        cfg.mix.mcast_pct = 0;
        let r = run(cfg, fabric(), StepMode::EventDriven);
        assert_eq!(r.completed, r.offered);
        assert_eq!(r.goodput_bytes, r.completed * 1024);
        assert_eq!(r.retried, 0, "no retry policy armed");
        assert_eq!(r.repaired_tasks, 0, "no faults armed");
        assert!((r.availability() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn retry_recovers_shed_requests() {
        let mut base = quick_cfg(30, AdmissionPolicy::Shed);
        base.queue_cap = 0;
        let without = run(base.clone(), fabric(), StepMode::EventDriven);
        assert!(without.rejected_shed > 0, "premise: this load sheds");
        let mut with = base;
        with.retry =
            RetryPolicy { max_attempts: 6, base_backoff: 128, max_backoff: 2048 };
        let r = run(with, fabric(), StepMode::EventDriven);
        assert!(r.retried > 0, "shed requests must enter the retry path");
        assert!(r.retry_attempts >= r.retried);
        assert!(
            r.completed > without.completed,
            "retry must convert sheds into completions ({} vs {})",
            r.completed,
            without.completed
        );
        assert!(r.rejected() < without.rejected());
        // Terminal-outcome conservation (the admitted-based identity is
        // for retry-off runs: a request can terminate Rejected here
        // without ever holding a slot).
        assert_eq!(r.offered, r.completed + r.failed + r.rejected() + r.unfinished);
    }

    #[test]
    fn retry_budget_is_bounded() {
        let mut cfg = quick_cfg(60, AdmissionPolicy::Shed);
        cfg.queue_cap = 0;
        cfg.max_inflight = 2;
        cfg.retry = RetryPolicy { max_attempts: 2, base_backoff: 64, max_backoff: 256 };
        let r = run(cfg, fabric(), StepMode::EventDriven);
        assert!(
            r.retry_attempts <= 2 * r.offered,
            "attempt budget exceeded: {} re-offers for {} requests",
            r.retry_attempts,
            r.offered
        );
        assert!(r.rejected_shed > 0, "past-saturation load must exhaust some budgets");
        assert_eq!(r.offered, r.completed + r.failed + r.rejected() + r.unfinished);
    }

    #[test]
    fn retry_replays_identically_by_seed() {
        let mut cfg = quick_cfg(30, AdmissionPolicy::Shed);
        cfg.queue_cap = 0;
        cfg.retry = RetryPolicy { max_attempts: 4, base_backoff: 128, max_backoff: 1024 };
        let a = run(cfg.clone(), fabric(), StepMode::EventDriven);
        let b = run(cfg, fabric(), StepMode::EventDriven);
        assert_eq!(a.dispositions, b.dispositions);
        assert_eq!(a.retry_attempts, b.retry_attempts);
        assert_eq!(a.cycles, b.cycles);
    }

    #[test]
    fn samples_cover_the_horizon() {
        let cfg = quick_cfg(8, AdmissionPolicy::Queue);
        let (every, horizon) = (cfg.sample_every, cfg.horizon);
        let r = run(cfg, fabric(), StepMode::EventDriven);
        assert_eq!(r.samples.len() as u64, horizon / every);
        for (i, s) in r.samples.iter().enumerate() {
            assert_eq!(s.cycle, (i as u64 + 1) * every);
        }
    }
}

//! Resilience benchmarks — wall-clock cost of serving under injected
//! faults (ISSUE 9), plus the availability/goodput/re-streamed-bytes
//! summary the baseline records.
//!
//! Three legs: a fail-stop faulted serving run (repair disarmed, client
//! retry only), the same schedule under resume+reroute repair, and the
//! quick resilience sweep (which re-asserts the resume/reroute
//! guarantees internally — a panic here is a correctness failure, not a
//! slow run). The simulated counters printed per leg are seed-exact and
//! machine-independent; only the milliseconds vary.
//!
//! CI integration mirrors `serve`: `TORRENT_BENCH_JSON` writes a
//! `torrent-bench-v1` baseline, `TORRENT_BENCH_BASELINE` compares p50s
//! against the committed `BENCH_resilience.json` and fails on >2x
//! calibrated regressions.

mod common;

use torrent::analysis::experiments;
use torrent::serve::{run, AdmissionPolicy, ArrivalKind, RetryPolicy, ServeConfig, ServeReport};
use torrent::sim::{FaultPlan, StepMode};
use torrent::soc::SocConfig;

fn cfg() -> ServeConfig {
    ServeConfig {
        seed: 17,
        horizon: 6_000,
        drain: 80_000,
        arrival: ArrivalKind::Poisson { rate_per_kcycle: 4 },
        policy: AdmissionPolicy::Queue,
        retry: RetryPolicy { max_attempts: 3, base_backoff: 256, max_backoff: 2_048 },
        ..ServeConfig::default()
    }
}

fn fabric(spec: &str) -> SocConfig {
    let plan = FaultPlan::parse(spec).expect("bench fault spec");
    SocConfig::custom(4, 4, 64 * 1024).with_faults(plan)
}

fn telemetry(r: &ServeReport) {
    println!(
        "  -> {} offered, {} completed, availability {:.4}, goodput {} B, \
         re-streamed {} B, repaired {}, retried {}, p99 = {} CC",
        r.offered,
        r.completed,
        r.availability(),
        r.goodput_bytes,
        r.restreamed_bytes,
        r.repaired_tasks,
        r.retried,
        r.p99()
    );
}

fn main() {
    common::banner("resilience: serving-under-faults benchmarks");
    let mut results: Vec<(String, f64)> = Vec::new();

    // 1. Fail-stop: the fault lands, repair is disarmed, only client
    // retry fights for availability. The wall-clock floor for a
    // degraded run.
    let mut last = None;
    let s = common::bench("resilience_4x4_failstop", 1, common::iters(5), || {
        last = Some(run(
            cfg(),
            fabric("router:5@1500;timeout:1200;norepair"),
            StepMode::EventDriven,
        ));
    });
    telemetry(&last.take().expect("bench ran"));
    results.push(("resilience_4x4_failstop".to_string(), s.p50));

    // 2. Same schedule with the full recovery stack armed: watermark
    // resume + path-diverse reroute. Buys availability back for the
    // price of the repair machinery — that price is what this leg
    // tracks.
    let s = common::bench("resilience_4x4_resume_reroute", 1, common::iters(5), || {
        last = Some(run(
            cfg(),
            fabric("router:5@1500;timeout:1200;resume;reroute"),
            StepMode::EventDriven,
        ));
    });
    telemetry(&last.take().expect("bench ran"));
    results.push(("resilience_4x4_resume_reroute".to_string(), s.p50));

    // 3. The quick sweep end-to-end: closed-loop probe + four policy
    // postures with every in-tree guarantee asserted. Panics on any
    // violation, so this leg is also a correctness smoke.
    let s = common::bench("resilience_quick_sweep", 0, common::iters(3), || {
        let (rows, _) = experiments::resilience_sweep(2025, true);
        assert_eq!(rows.len(), 4, "quick sweep emits one row per policy");
    });
    results.push(("resilience_quick_sweep".to_string(), s.p50));

    // Baseline plumbing (see Makefile `bench-baseline` / `resilience-smoke`).
    if let Ok(path) = std::env::var("TORRENT_BENCH_JSON") {
        let calibrated = std::env::var("TORRENT_BENCH_CALIBRATED").is_ok();
        let note = if calibrated {
            "calibrated from a real run via `make bench-baseline`"
        } else {
            "placeholder written without calibration; run `make bench-baseline`"
        };
        common::write_bench_json(&path, "resilience", calibrated, note, &results)
            .expect("write bench JSON");
        println!("wrote baseline {path} (calibrated={calibrated})");
    }
    if let Ok(path) = std::env::var("TORRENT_BENCH_BASELINE") {
        common::banner("resilience: baseline comparison");
        match common::read_bench_json(&path) {
            Err(e) => {
                eprintln!("baseline unavailable: {e}");
                std::process::exit(1);
            }
            Ok(base) => {
                let regressions = common::count_regressions(&results, &base);
                if regressions > 0 {
                    eprintln!("{regressions} bench regression(s) vs {path}");
                    std::process::exit(1);
                }
            }
        }
    }
}
